"""Top-k gather equivalence for the two-phase BGPP paged decode.

The tentpole contract of the access-reduced path: phase 1 predicts the
top-k candidate set from bit-slice planes alone, phase 2 gathers ONLY the
surviving tokens' full-precision rows through the page table — and the
resulting logits are BIT-identical to the full-entry BGPP attend (the slot
layout's path, and ``paged_entry``'s full-row gather view).  Checked for
cache fills below / at / above the keep budget ``K = ceil(keep_ratio · S)``
and across a page boundary, on a deliberately shuffled (non-identity) page
table so logical->physical translation is actually exercised.

Also pins the kv-read accounting that rides the same plan: paged bgpp
decode reads bit-planes plus at most ``K`` full-precision rows per
(slot, layer) — the ISSUE-5 acceptance assert.
"""

import dataclasses
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import MCBPOptions
from repro.serving import engine, kv_cache as kvc

jax.config.update("jax_platform_name", "cpu")

B, S_MAX, PAGE = 2, 32, 8
KEEP = 0.25  # K = ceil(0.25 * 32) = 8 keys kept at full precision


def _cfg():
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    return dataclasses.replace(
        cfg, mcbp=MCBPOptions(bgpp_rounds=4, bgpp_keep_ratio=KEEP)
    )


def _filled_caches(cfg, s_ctx, seed):
    """Write the SAME random K/V into a paged store (shuffled page table)
    and a slot store, returning (paged cache, slot cache, q, valid)."""
    rng = np.random.default_rng(seed)
    lp = kvc.layout_for(cfg, B, S_MAX, kv_format="bgpp", layout="paged",
                        page_size=PAGE)
    ls = kvc.layout_for(cfg, B, S_MAX, kv_format="bgpp")
    paged = kvc.init_cache_arrays(cfg, lp)
    slot = kvc.init_cache_arrays(cfg, ls)

    # non-identity mapping: slot rows land on permuted physical pages, so
    # a gather that forgot to translate would read the wrong tokens
    tbl = np.full((B, lp.pages_per_slot), -1, np.int32)
    perm = rng.permutation(lp.num_pages)
    npg = -(-s_ctx // PAGE)
    for b in range(B):
        tbl[b, :npg] = perm[b * lp.pages_per_slot:b * lp.pages_per_slot + npg]
    paged["page_table"] = jnp.asarray(tbl)

    Hk, Dh, Hq = cfg.num_kv_heads, cfg.head_dim, cfg.num_heads
    k = jnp.asarray(rng.normal(size=(B, s_ctx, Hk, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, s_ctx, Hk, Dh)), jnp.float32)
    for b in range(B):
        paged["global"] = kvc.write_prefill(
            paged["global"], 0, k[b:b + 1], v[b:b + 1], slot=b,
            page_table=paged["page_table"], page_size=PAGE, max_seq=S_MAX,
        )
        slot["global"] = kvc.write_prefill(
            slot["global"], 0, k[b:b + 1], v[b:b + 1], slot=b,
        )
    q = jnp.asarray(rng.normal(size=(B, Hq, Dh)), jnp.float32)
    valid = jnp.arange(S_MAX)[None, :] < s_ctx
    return paged, slot, q, valid


class TestTopkGatherEquivalence:
    # K = 8: fills straddle the keep budget, and 13/30 span page boundaries
    @pytest.mark.parametrize("s_ctx", [5, 8, 13, 30])
    def test_two_phase_matches_full_entry(self, s_ctx):
        cfg = _cfg()
        paged, slot, q, valid = _filled_caches(cfg, s_ctx, seed=s_ctx)
        phys = kvc.phys_table(paged["page_table"], PAGE, S_MAX)

        two_phase = np.asarray(engine._bgpp_paged_decode_attend(
            q, paged["global"], 0, phys, valid, cfg
        ))
        # full-entry reference #1: the whole paged row gathered back into
        # the heads-major view (the pre-two-phase paged path)
        full_view = kvc.paged_entry(paged["global"], 0, phys)
        full_paged = np.asarray(engine._bgpp_decode_attend(
            q, full_view, valid, cfg
        ))
        # full-entry reference #2: the slot layout's dense row
        entry_slot = {n: slot["global"][n][0] for n in slot["global"]}
        full_slot = np.asarray(engine._bgpp_decode_attend(
            q, entry_slot, valid, cfg
        ))

        assert np.array_equal(two_phase, full_paged), (
            f"s_ctx={s_ctx}: two-phase attend diverges from the full "
            f"paged-entry BGPP path "
            f"(max |d| {np.max(np.abs(two_phase - full_paged))})"
        )
        assert np.array_equal(two_phase, full_slot), (
            f"s_ctx={s_ctx}: two-phase attend diverges from the slot "
            f"layout (max |d| {np.max(np.abs(two_phase - full_slot))})"
        )

    def test_compacted_buffer_is_keep_ratio_sized(self):
        """Phase 2's gather is fixed-shape: exactly K = ceil(keep·S) token
        rows per (slot, head), never the full row."""
        cfg = _cfg()
        paged, _, q, valid = _filled_caches(cfg, 13, seed=0)
        phys = kvc.phys_table(paged["page_table"], PAGE, S_MAX)
        qf = engine._bgpp_quant_query(q, cfg)
        idx, idx_valid = engine._bgpp_topk_indices(
            qf,
            kvc.paged_plane(paged["global"], 0, kvc.NBITS - 1, phys),
            kvc.paged_sign(paged["global"], 0, phys),
            lambda p, i: kvc.paged_plane_rows(
                paged["global"], 0, p, kvc.paged_rows_at(phys, i)
            ),
            valid, cfg,
        )
        k_max = math.ceil(KEEP * S_MAX)
        assert idx.shape == (B, cfg.num_kv_heads, k_max)
        gathered = kvc.paged_topk_entry(
            paged["global"], 0, kvc.paged_rows_at(phys, idx)
        )
        Hk, Dh = cfg.num_kv_heads, cfg.head_dim
        assert gathered["k_planes"].shape == (kvc.NBITS, B, Hk, k_max, Dh // 8)
        assert gathered["v"].shape == (B, Hk, k_max, Dh)
        assert gathered["k_scale"].shape == (B, Hk, k_max)
        # with 13 valid tokens and K=8, every candidate lane is real
        assert bool(np.all(np.asarray(idx_valid)))


def _iter_avals(jaxpr):
    """Every intermediate aval in a jaxpr, recursing into sub-jaxprs
    (pjit/scan/cond bodies) — duck-typed so it tracks JAX versions."""
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None:
                yield aval
        for val in eqn.params.values():
            for sub in (val if isinstance(val, (list, tuple)) else [val]):
                inner = getattr(sub, "jaxpr", None)  # ClosedJaxpr
                if inner is not None and hasattr(inner, "eqns"):
                    yield from _iter_avals(inner)
                elif hasattr(sub, "eqns"):  # raw Jaxpr
                    yield from _iter_avals(sub)


class TestServeStepAccessStructure:
    def test_paged_bgpp_serve_step_never_materializes_full_rows(self):
        """Couple the kv-read counter's claim to the ACTUAL decode graph:
        trace the real ``serve_step`` for a paged bgpp layout and assert
        no intermediate carries a full-width int8 KV row ``(B, S, Hk, Dh)``
        (either axis order).  If the engine ever regressed to the
        ``paged_entry`` full-row gather, such a tensor must appear — shown
        by the positive control, which traces the full-entry reference and
        requires the detector to fire.  (Bit-plane tensors are uint8 and
        the compacted phase-2 buffers are K-wide, so the two-phase graph
        is clean by construction.)"""
        from repro.models import model_zoo

        cfg = _cfg()
        params, _ = model_zoo.init(jax.random.key(0), cfg)
        lp = kvc.layout_for(cfg, B, S_MAX, kv_format="bgpp", layout="paged",
                            page_size=PAGE)
        cache = kvc.init_cache_arrays(cfg, lp)
        cache["page_table"] = kvc.identity_page_table(lp)
        step = engine.make_serve_step(cfg, lp)
        closed = jax.make_jaxpr(step)(
            params, cache, jnp.zeros((B, 1), jnp.int32)
        )
        Hk, Dh = cfg.num_kv_heads, cfg.head_dim
        forbidden = {(B, S_MAX, Hk, Dh), (B, Hk, S_MAX, Dh)}

        def full_row_avals(jaxpr):
            return [
                a for a in _iter_avals(jaxpr)
                if getattr(a, "dtype", None) == jnp.int8
                and tuple(getattr(a, "shape", ())) in forbidden
            ]

        assert not full_row_avals(closed.jaxpr), (
            "paged bgpp serve_step materialized a full-width int8 KV row —"
            " the two-phase gather regressed to a full-entry gather"
        )

        # positive control: the detector must fire on the full-entry path
        phys = kvc.phys_table(cache["page_table"], PAGE, S_MAX)
        valid = jnp.ones((B, S_MAX), bool)
        q = jnp.zeros((B, cfg.num_heads, Dh), jnp.float32)
        ref = jax.make_jaxpr(
            lambda q_, store, phys_: engine._bgpp_decode_attend(
                q_, kvc.paged_entry(store, 0, phys_), valid, cfg
            )
        )(q, cache["global"], phys)
        assert full_row_avals(ref.jaxpr), (
            "detector lost sensitivity: the full-entry reference no longer"
            " shows a full-width int8 row"
        )

    def test_kernel_dispatch_serve_step_never_materializes_full_rows(
        self, monkeypatch
    ):
        """The same structural claim on the KERNEL-routed decode graph:
        with ``decode_kernel=interpret`` the global attend becomes a
        ``pallas_call`` over the token-major pools, and the traced step —
        including every sub-jaxpr the interpreter carries — must still
        never hold a full-width int8 KV row.  The kernel consumes packed
        planes and gathers k_max compacted rows per (b, h) cell, so a
        full-row aval appearing here means the dispatch path regressed to
        a dense-entry gather."""
        from repro.models import model_zoo
        from repro.serving import kernel_decode

        monkeypatch.setenv(kernel_decode.ENV_VAR, "interpret")
        cfg = _cfg()
        params, _ = model_zoo.init(jax.random.key(0), cfg)
        lp = kvc.layout_for(cfg, B, S_MAX, kv_format="bgpp", layout="paged",
                            page_size=PAGE)
        cache = kvc.init_cache_arrays(cfg, lp)
        cache["page_table"] = kvc.identity_page_table(lp)
        step = engine.make_serve_step(cfg, lp)
        closed = jax.make_jaxpr(step)(
            params, cache, jnp.zeros((B, 1), jnp.int32)
        )
        Hk, Dh = cfg.num_kv_heads, cfg.head_dim
        forbidden = {(B, S_MAX, Hk, Dh), (B, Hk, S_MAX, Dh)}
        hits = [
            a for a in _iter_avals(closed.jaxpr)
            if getattr(a, "dtype", None) == jnp.int8
            and tuple(getattr(a, "shape", ())) in forbidden
        ]
        assert not hits, (
            "kernel-dispatch paged bgpp serve_step materialized a "
            "full-width int8 KV row — the fused kernel path regressed to "
            "a full-entry gather"
        )


class TestKvReadAccounting:
    def test_bgpp_reads_planes_plus_at_most_keep_full_rows(self):
        """The ISSUE-5 acceptance bound, via the counter the scheduler
        threads to stats(): full-precision rows per (slot, layer) never
        exceed ceil(keep_ratio * S), and everything else is plane-sized."""
        cfg = _cfg()
        lp = kvc.layout_for(cfg, B, S_MAX, kv_format="bgpp", layout="paged",
                            page_size=PAGE)
        r = kvc.decode_read_bytes(lp, cfg)
        assert r["bgpp"]["full_rows_per_slot"] == math.ceil(KEEP * S_MAX)
        assert r["bgpp"]["full_rows_per_slot"] <= math.ceil(
            cfg.mcbp.bgpp_keep_ratio * S_MAX
        )
        # the global-stack read decomposes exactly into sign + planes +
        # top-k full rows — nothing else is fetched
        parts = (r["bgpp"]["sign_bytes"] + r["bgpp"]["plane_bytes"]
                 + r["bgpp"]["topk_full_bytes"])
        assert parts == pytest.approx(r["global"])
        assert r["total"] < r["bf16_equiv"]

    def test_format_ordering_and_slot_paged_agree(self):
        cfg = _cfg()
        totals = {}
        for fmt in ("bf16", "int8", "bgpp"):
            ls = kvc.layout_for(cfg, B, S_MAX, kv_format=fmt)
            lp = kvc.layout_for(cfg, B, S_MAX, kv_format=fmt, layout="paged",
                                page_size=PAGE)
            # the layout changes where rows live, not how many bytes one
            # decode step must fetch
            assert kvc.decode_read_bytes(ls, cfg) == kvc.decode_read_bytes(lp, cfg)
            totals[fmt] = kvc.decode_read_bytes(ls, cfg)["total"]
        assert totals["bgpp"] < totals["int8"] < totals["bf16"]

    def test_chunk_read_is_full_precision(self):
        """Prefill has nothing to skip: the chunk attend reads the whole
        row at full precision for every format."""
        cfg = _cfg()
        for fmt in ("bf16", "int8", "bgpp"):
            layout = kvc.layout_for(cfg, B, S_MAX, kv_format=fmt)
            c = kvc.chunk_read_bytes(layout, cfg)
            assert c["total"] == pytest.approx(
                len(layout.global_layers) * S_MAX
                * kvc._token_row_bytes(cfg, fmt)
            )
