"""End-to-end serving driver: batched requests through prefill + decode
with the MCBP stack (int8 or bit-planar BGPP KV cache).

    PYTHONPATH=src python examples/serve_llm.py [--arch phi4-mini-3.8b]
        [--kv-format int8|bf16|bgpp] [--steps 24] [--batch 4]

Uses the smoke-sized config of the chosen architecture (CPU container);
the identical engine code path is what the decode_32k / long_500k dry-run
cells lower for the production meshes.
"""

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ARCH_REGISTRY, get_config
from repro.models import model_zoo
from repro.serving import engine, kv_cache as kvc

jax.config.update("jax_platform_name", "cpu")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b", choices=sorted(ARCH_REGISTRY))
    ap.add_argument("--kv-format", default="int8", choices=["bf16", "int8", "bgpp"])
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if cfg.family not in ("dense", "moe", "vlm"):
        raise SystemExit("this driver serves transformer families; "
                         "see tests/test_serving.py for ssm/hybrid/enc-dec")
    rng = np.random.default_rng(0)
    params, _ = model_zoo.init(jax.random.key(0), cfg)
    max_seq = args.prompt_len + args.steps + 8

    # batched "requests": random prompts (no tokenizer in the container)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )

    layout = kvc.layout_for(cfg, args.batch, max_seq, kv_format=args.kv_format)
    t0 = time.perf_counter()
    last_logits, cache = engine.prefill(
        params, cfg, layout, prompts, block_q=16, block_k=32
    )
    jax.block_until_ready(last_logits)
    t_prefill = time.perf_counter() - t0
    print(f"[serve] arch={cfg.name} kv={args.kv_format} "
          f"prefill {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f} ms")
    print(f"[serve] cache: {kvc.cache_bytes(cache)/1e6:.2f} MB "
          f"({len(layout.global_layers)} global / {len(layout.local_layers)} local layers)")

    serve_step = jax.jit(engine.make_serve_step(cfg, layout))
    cur = jnp.argmax(last_logits[:, -1], -1).astype(jnp.int32)[:, None]
    out_tokens = [cur]
    t0 = time.perf_counter()
    for _ in range(args.steps):
        logits, cache = serve_step(params, cache, cur)
        cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out_tokens.append(cur)
    jax.block_until_ready(cur)
    dt = time.perf_counter() - t0
    toks = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"[serve] decoded {args.steps} steps x {args.batch} seqs in "
          f"{dt*1e3:.1f} ms ({args.steps*args.batch/dt:.1f} tok/s on CPU smoke)")
    for b in range(min(args.batch, 2)):
        print(f"[serve] seq{b}: {toks[b][:16].tolist()}...")


if __name__ == "__main__":
    main()
