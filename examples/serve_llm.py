"""End-to-end serving demo: batched requests through the continuous-batching
scheduler (per-slot prefill + decode with int8 or bit-planar BGPP KV cache).

    PYTHONPATH=src python examples/serve_llm.py [--arch phi4-mini-3.8b]
        [--kv-format int8|bf16|bgpp] [--steps 24] [--batch 4]

Each request is admitted into its own slot of ONE live cache
(``engine.prefill_into_slot``) and all slots decode together in a single
batched serve_step per token — the identical engine code path the
decode_32k / long_500k dry-run cells lower for the production meshes.
Uses the smoke-sized config of the chosen architecture (CPU container).
"""

import argparse
import time

import numpy as np

import jax

from repro.configs import ARCH_REGISTRY, get_config
from repro.models import model_zoo
from repro.serving import kv_cache as kvc
from repro.serving.request import Request
from repro.serving.scheduler import Scheduler

jax.config.update("jax_platform_name", "cpu")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b", choices=sorted(ARCH_REGISTRY))
    ap.add_argument("--kv-format", default="int8", choices=["bf16", "int8", "bgpp"])
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if cfg.family not in ("dense", "moe", "vlm"):
        raise SystemExit("this driver serves transformer families; "
                         "see tests/test_serving.py for ssm/hybrid/enc-dec")
    rng = np.random.default_rng(0)
    params, _ = model_zoo.init(jax.random.key(0), cfg)
    max_seq = args.prompt_len + args.steps + 8

    layout = kvc.layout_for(cfg, args.batch, max_seq, kv_format=args.kv_format)
    sched = Scheduler(params, cfg, layout,
                      prefill_kw=dict(block_q=16, block_k=32))

    # batched "requests": random prompts of varying length (no tokenizer in
    # the container); +1 because admission itself samples the first token
    t0 = time.perf_counter()
    for rid in range(args.batch):
        plen = max(4, args.prompt_len - 3 * rid)
        sched.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, (plen,)).astype(np.int32),
            max_new_tokens=args.steps + 1,
        ))
    sched.admit()
    jax.block_until_ready(sched.cache["pos"])
    t_prefill = time.perf_counter() - t0
    print(f"[serve] arch={cfg.name} kv={args.kv_format} "
          f"prefill {args.batch} slots (longest {args.prompt_len}) "
          f"in {t_prefill*1e3:.1f} ms")
    print(f"[serve] cache: {kvc.cache_bytes(sched.cache)/1e6:.2f} MB "
          f"({len(layout.global_layers)} global / "
          f"{len(layout.local_layers)} local layers)")

    t0 = time.perf_counter()
    sched.run(max_steps=args.steps)
    dt = time.perf_counter() - t0
    done = sched.finished + [s.request for s in sched.slots if s.request]
    print(f"[serve] decoded {args.steps} steps x {args.batch} seqs in "
          f"{dt*1e3:.1f} ms ({sched.decoded_tokens/dt:.1f} tok/s on CPU "
          f"smoke, occupancy {np.mean(sched.occupancy):.2f})")
    for req in sorted(done, key=lambda r: r.rid)[:2]:
        print(f"[serve] seq{req.rid}: {req.generated[:16]}...")


if __name__ == "__main__":
    main()
