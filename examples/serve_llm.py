"""End-to-end serving demo: batched requests through the continuous-batching
scheduler (chunked prefill admission + per-slot decode with int8 or
bit-planar BGPP KV cache).

    PYTHONPATH=src python examples/serve_llm.py [--arch phi4-mini-3.8b]
        [--kv-format int8|bf16|bgpp] [--admission chunked|eager]
        [--kv-layout slot|paged] [--page-size 8] [--shared-prefix 16]
        [--weight-format bf16|int8|bstc] [--server]
        [--spec-decode] [--draft-gamma 4] [--draft-planes 4]
        [--chunk-budget 8] [--steps 24] [--batch 4] [--mesh 2,4]

``--server`` swaps the offline replay for the asyncio front door
(``repro.serving.server``) and showcases its three signature moves: a
two-turn chat session whose second turn adopts the first turn's pinned
KV pages through the sha1 prefix index (``--kv-layout paged``), an
interactive arrival preempting a batch prompt's chunked prefill, and a
client that disconnects mid-stream (slot evicted, pages freed, nobody
else perturbed).

Each request is admitted into its own slot of ONE live cache — by default
through fixed-shape prefill chunks (``engine.ChunkedPrefill``, jitted once
per bucket width with the cache donated) interleaved with decode, so slots
already decoding never stall behind a long prompt — and all live slots
decode together in a single batched serve_step per token, the identical
engine code path the decode_32k / long_500k dry-run cells lower for the
production meshes.  Uses the smoke-sized config of the chosen architecture
(CPU container).
"""

import argparse
import time

import numpy as np

import jax

from repro.configs import (ARCH_REGISTRY, WEIGHT_FORMATS,
                           apply_bgpp_overrides,
                           apply_decode_kernel_override,
                           apply_spec_decode_overrides,
                           apply_weight_format_override, get_config)
from repro.models import model_zoo
from repro.serving import kv_cache as kvc
from repro.serving import sharded as shd
from repro.serving.request import Request
from repro.serving.scheduler import Scheduler

jax.config.update("jax_platform_name", "cpu")


def run_server_demo(sched, cfg, rng):
    """Drive the asyncio front door end to end: a two-turn chat session
    (turn 2 adopts turn 1's pinned pages on paged layouts), an interactive
    turn preempting a batch prompt's chunked prefill, and a mid-stream
    client disconnect — with the per-step page-leak gate armed."""
    import asyncio

    from repro.serving.server import AsyncServer

    def prompt(n):
        return rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)

    async def collect(stream):
        return [t async for t in stream]

    async def demo():
        server = AsyncServer(sched, check_invariants=True)
        pump = asyncio.ensure_future(server.run())
        t1 = server.chat("demo", prompt(24), 6)
        print(f"[server] chat turn 1 -> {await collect(t1)}")
        # turn 2 races a batch client; the interactive tier preempts its
        # chunked prefill, then turn 2's prompt head comes from the index
        batch = server.submit(prompt(20), 6, priority="batch")
        t2 = server.chat("demo", prompt(8), 6,
                         arrival_step=sched.step_count + 1)
        got2, gotb = await asyncio.gather(collect(t2), collect(batch))
        print(f"[server] chat turn 2 -> {got2} (adopted "
              f"{t2.request.prefix_reused_tokens} history tokens from the "
              f"prefix index)")
        print(f"[server] batch client -> {gotb} "
              f"(prefill preempted {batch.request.preemptions}x)")
        gone = server.submit(prompt(12), 32)
        seen = []
        async for tok in gone:
            seen.append(tok)
            if len(seen) == 2:
                await gone.cancel()
                break
        print(f"[server] disconnecting client got {seen}, then hung up "
              f"(cancelled while {gone.request.cancel_state})")
        server.close_session("demo")
        await server.drain()
        server.close()
        await pump
        return server.stats()

    stats = asyncio.run(demo())
    print(f"[server] totals: finished={stats['finished_requests']} "
          f"cancelled={stats['cancelled_requests']} "
          f"preemptions={stats['preemptions']}")
    for tier, t in stats["tiers"].items():
        print(f"[server] tier {tier}: finished={t['finished']} "
              f"cancelled={t['cancelled']} ttft_s p50={t['ttft_s']['p50']} "
              f"itl_s p50={t['itl_s']['p50']}")
    if "paged" in stats:
        print(f"[server] paged: prefix hit rate "
              f"{stats['paged']['prefix_hit_rate']:.3f}, pages in use "
              f"{stats['paged']['pages_in_use']} (pool drained)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b", choices=sorted(ARCH_REGISTRY))
    ap.add_argument("--kv-format", default="int8", choices=["bf16", "int8", "bgpp"])
    ap.add_argument("--kv-layout", default="slot", choices=["slot", "paged"])
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="shared system-prompt tokens prepended to every "
                         "request (paged layouts reuse their pages)")
    ap.add_argument("--admission", default="chunked", choices=["chunked", "eager"])
    ap.add_argument("--bgpp-rounds", type=int, default=None,
                    help="bgpp progressive rounds (default: config's)")
    ap.add_argument("--bgpp-keep-ratio", type=float, default=None,
                    help="fraction of keys the bgpp decode keeps at full "
                         "precision (default: config's)")
    ap.add_argument("--decode-kernel", default=None,
                    choices=["auto", "jnp", "interpret", "kernel"],
                    help="global-layer decode attend: jnp (legacy) or the "
                         "Pallas paged-attention kernels (default: config's)")
    ap.add_argument("--weight-format", default=None,
                    choices=sorted(WEIGHT_FORMATS),
                    help="serve-time weight numerics for decode projections "
                         "(bf16 raw default; int8/bstc quantized records "
                         "with weight_read pricing) (default: config's)")
    ap.add_argument("--server", action="store_true",
                    help="demo the asyncio front door instead: two-turn "
                         "chat session (prefix-index reuse across turns), "
                         "priority preemption, and a mid-stream disconnect")
    ap.add_argument("--spec-decode", action="store_true",
                    help="bit-plane speculative decoding: truncated-plane "
                         "drafts + batched verify/rollback, bit-identical "
                         "output with an accepted-tokens/step report")
    ap.add_argument("--draft-gamma", type=int, default=None,
                    help="draft tokens per slot per speculative round "
                         "(default: config's)")
    ap.add_argument("--draft-planes", type=int, default=None,
                    help="MSB magnitude bit-planes kept in the draft "
                         "weights, 1-8 (default: config's)")
    ap.add_argument("--chunk-budget", type=int, default=8)
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--mesh", default=None,
                    help="DATA,MODEL mesh shape (e.g. 2,4) to shard the "
                         "serve_step: KV pools heads-parallel on model, "
                         "slots on data.  Needs data*model devices "
                         "(XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=8 on CPU); default single-device")
    args = ap.parse_args()

    cfg = apply_bgpp_overrides(
        get_config(args.arch, smoke=True),
        rounds=args.bgpp_rounds, keep_ratio=args.bgpp_keep_ratio,
    )
    cfg = apply_decode_kernel_override(cfg, args.decode_kernel)
    cfg = apply_weight_format_override(cfg, args.weight_format)
    cfg = apply_spec_decode_overrides(cfg, enabled=args.spec_decode or None,
                                      gamma=args.draft_gamma,
                                      planes=args.draft_planes)
    if cfg.family not in ("dense", "moe", "vlm"):
        raise SystemExit("this driver serves transformer families; "
                         "see tests/test_serving.py for ssm/hybrid/enc-dec")
    rng = np.random.default_rng(0)
    params, _ = model_zoo.init(jax.random.key(0), cfg)
    max_seq = args.prompt_len + args.steps + 8

    layout = kvc.layout_for(cfg, args.batch, max_seq + args.shared_prefix,
                            kv_format=args.kv_format,
                            layout=args.kv_layout, page_size=args.page_size)
    kw = {}
    if args.mesh:
        d, m = shd.parse_mesh_arg(args.mesh)
        kw["rules"] = shd.rules_for(d, m)
    sched = Scheduler(params, cfg, layout, admission=args.admission,
                      chunk_budget=args.chunk_budget,
                      prefill_kw=dict(block_q=16, block_k=32), **kw)
    print(f"[serve] cache: {kvc.cache_bytes(sched.cache)/1e6:.2f} MB "
          f"({len(layout.global_layers)} global / "
          f"{len(layout.local_layers)} local layers)")

    if args.server:
        run_server_demo(sched, cfg, rng)
        return

    # batched "requests": random prompts of varying length (no tokenizer in
    # the container); +1 because admission itself samples the first token.
    # --shared-prefix prepends one common "system prompt" to all of them and
    # staggers arrivals — prefix reuse needs a resident donor, so a request
    # must arrive after another has prefilled the shared pages.
    prefix = rng.integers(0, cfg.vocab_size, (args.shared_prefix,)).astype(np.int32)
    for rid in range(args.batch):
        plen = max(4, args.prompt_len - 3 * rid)
        sched.submit(Request(
            rid=rid,
            prompt=np.concatenate([
                prefix,
                rng.integers(0, cfg.vocab_size, (plen,)).astype(np.int32),
            ]),
            max_new_tokens=args.steps + 1,
            arrival_step=(args.shared_prefix // 2) * rid,
        ))

    t0 = time.perf_counter()
    sched.run(max_steps=10_000)
    dt = time.perf_counter() - t0
    stats = sched.stats(dt)
    print(f"[serve] arch={cfg.name} kv={args.kv_format} "
          f"admission={args.admission}: decoded {stats['decoded_tokens']} "
          f"tokens across {args.batch} seqs in {dt*1e3:.1f} ms "
          f"({stats['tokens_per_s']:.1f} tok/s on CPU smoke, "
          f"occupancy {stats['mean_occupancy']:.2f})")
    print(f"[serve] ttft_s p50={stats['ttft_s']['p50']} "
          f"p95={stats['ttft_s']['p95']}  itl_s p50={stats['itl_s']['p50']} "
          f"p95={stats['itl_s']['p95']}  "
          f"max prefill tokens/step={stats['max_prefill_tokens_per_step']}")
    kv = stats["kv_read"]
    print(f"[serve] kv read/decode-step: {kv['decode_bytes_per_step']/1e3:.1f}"
          f" kB vs {kv['decode_bf16_equiv_bytes_per_step']/1e3:.1f} kB "
          f"bf16-equivalent ({kv['decode_bytes_reduction_vs_bf16']}x); "
          f"bgpp full rows/slot/layer: "
          f"{kv.get('bgpp', {}).get('full_rows_per_slot', '-')}")
    wr = stats["weight_read"]
    print(f"[serve] weight read/decode-step ({wr['weight_format']}): "
          f"{wr['decode_bytes_per_step']/1e3:.1f} kB vs "
          f"{wr['decode_bf16_equiv_bytes_per_step']/1e3:.1f} kB "
          f"bf16-equivalent ({wr['decode_bytes_reduction_vs_bf16']}x, "
          f"measured/modeled {wr['measured_over_modeled']})")
    if args.mesh:
        print(f"[serve] mesh {kv['mesh']['data']}x{kv['mesh']['model']}: "
              f"{kv['decode_bytes_per_device_per_step']/1e3:.1f} kB/device/"
              f"step over {kv['kv_shards']} kv shards, interconnect "
              f"{kv['interconnect_bytes_per_step']/1e3:.2f} kB/step")
    if "spec" in stats:
        sp = stats["spec"]
        print(f"[serve] spec decode (gamma={sp['gamma']}, "
              f"planes={sp['draft_planes']}): "
              f"accepted/step={sp['accepted_tokens_per_step']:.3f}, "
              f"{sp['accepted_tokens_per_round']:.2f} accepted/round, "
              f"kv {sp['kv_bytes_per_accepted_token']/1e3:.1f} kB and weight "
              f"{sp['weight_bytes_per_accepted_token']/1e3:.1f} kB per "
              f"accepted token")
    if "paged" in stats:
        pg = stats["paged"]
        print(f"[serve] paged: prefix hit rate {pg['prefix_hit_rate']:.3f}, "
              f"resident KV peak {pg['resident_kv_bytes_peak']/1e3:.1f} kB "
              f"vs {pg['slot_resident_kv_bytes']/1e3:.1f} kB slot-dense, "
              f"pages_in_use={pg['pages_in_use']}")
    for req in sorted(sched.finished, key=lambda r: r.rid)[:2]:
        print(f"[serve] seq{req.rid}: {req.generated[:16]}...")


if __name__ == "__main__":
    main()
