"""BGPP walkthrough: progressive bit-grained prediction on a realistic
attention distribution, showing per-round pruning, early termination, and
the traffic/recall trade-off vs the value-level top-k baseline (paper
Figs. 3, 5(e,g), 9).

    PYTHONPATH=src python examples/bgpp_sparse_attention.py
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import bgpp, topk

jax.config.update("jax_platform_name", "cpu")


def make_concentrated_keys(rng, S, D, n_relevant=32):
    """Keys where a few are aligned with the query (real attention is
    concentrated — paper §2.2's premise)."""
    q = rng.normal(size=(D,)).astype(np.float32)
    k = rng.normal(size=(S, D)).astype(np.float32)
    idx = rng.choice(S, n_relevant, replace=False)
    k[idx] += q * rng.uniform(1.0, 2.5, size=(n_relevant, 1))
    k_int = np.clip(np.round(k * 25), -127, 127).astype(np.int32)
    q_int = np.clip(np.round(q * 25), -127, 127).astype(np.int32)
    return q_int, k_int, set(idx.tolist())


def main():
    rng = np.random.default_rng(0)
    S, D = 4096, 128
    q, k, relevant = make_concentrated_keys(rng, S, D)

    sign = jnp.asarray((k < 0).astype(np.uint8))
    mag = np.abs(k).astype(np.uint8)
    planes = jnp.asarray(np.stack([(mag >> p) & 1 for p in range(7)], 0))
    qj = jnp.asarray(q)
    scale = 1.0 / (25 * 25 * np.sqrt(D))

    # ground truth: softmax distribution (what the attention output sees)
    logits = (k @ q).astype(np.float64) * scale
    p = np.exp(logits - logits.max())
    p /= p.sum()

    # the metric that matters: softmax mass captured by the surviving keys
    # (keys far below the max contribute nothing to the output — that's the
    # paper's radius insight: gap > radius ⇒ softmax ≈ 0)
    print(f"{'alpha':>6} {'rounds':>6} {'kept':>6} {'mass':>7} "
          f"{'traffic_vs_full':>15} {'vs_value_topk':>13}")
    for alpha in (0.4, 0.5, 0.55, 0.6):
        for rounds in (2, 4, 6):
            alive, est, stats = bgpp.bgpp_predict(
                qj, planes, sign,
                bgpp.BGPPConfig(rounds=rounds, alpha=alpha),
                logit_scale=scale,
            )
            mask = np.asarray(alive)
            mass = float(p[mask].sum())
            frac = float(stats.predict_bytes) / (S * D)
            vs_value = float(stats.predict_bytes) / float(stats.value_topk_bytes)
            print(f"{alpha:>6} {rounds:>6} {int(mask.sum()):>6} {mass:>7.4f} "
                  f"{frac:>15.3f} {vs_value:>13.3f}")

    # value-level baseline for the same fidelity
    idx, _, vstats = topk.value_topk_predict(qj, jnp.asarray(k, jnp.int8), k_keep=256)
    mass_v = float(p[np.asarray(idx)].sum())
    print(f"\nvalue-level top-256: mass {mass_v:.4f}, predict bytes "
          f"{float(vstats.predict_bytes):.0f} — BGPP reaches the same mass "
          f"while fetching bit-planes of survivors only")

    alive, _, stats = bgpp.bgpp_predict(
        qj, planes, sign, bgpp.BGPPConfig(rounds=7, alpha=0.55), logit_scale=scale
    )
    hist = np.asarray(stats.alive_per_round)
    print(f"\nper-round alive counts (early termination visible): {hist.tolist()}")
    mask = np.asarray(alive)
    heavy = p > 1e-3  # keys that actually matter to the output
    print(f"softmax mass kept: {float(p[mask].sum()):.4f}; "
          f"heavy-key recall (p>1e-3): {float(mask[heavy].mean()):.3f}")


if __name__ == "__main__":
    main()
