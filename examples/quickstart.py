"""Quickstart: MCBP's three techniques on one weight matrix, end to end.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import bgpp, bitslice, brcr, bstc, quantization
from repro.utils.synthetic import synthetic_llm_weight

jax.config.update("jax_platform_name", "cpu")


def main():
    rng = np.random.default_rng(0)

    # --- quantize an LLM-like weight (per-channel symmetric INT8) ---------
    w = jnp.asarray(synthetic_llm_weight(rng, (64, 1024)))
    qw = quantization.quantize_weight(w)
    _, mag = bitslice.to_sign_magnitude(qw.q)
    sp = np.asarray(bitslice.bit_sparsity(bitslice.bitplanes(mag)))
    print(f"bit-plane sparsity (LSB→MSB): {np.round(sp, 3)}")
    print(f"value sparsity: {float((np.asarray(qw.q) == 0).mean()):.3f}")

    # --- BRCR: exact GEMM through the enumeration factorization -----------
    x = jnp.asarray(rng.integers(-50, 50, size=(1024, 16)), jnp.int32)
    y = brcr.brcr_matmul(qw.q, x, m=4)
    ref = jnp.asarray(np.asarray(qw.q, np.int64) @ np.asarray(x, np.int64))
    cost = brcr.brcr_cost(qw.q, m=4)
    print(f"\nBRCR exact: {bool((y == ref).all())}")
    print(f"BRCR adds: {cost.adds_total}  vs bit-serial: {cost.adds_bsc_baseline} "
          f"({100*cost.reduction_vs_bsc:.1f}% fewer)")

    # --- BSTC: lossless two-state weight compression -----------------------
    bw = bstc.encode_weight(np.asarray(qw.q), np.asarray(qw.scale))
    rt = np.asarray(bstc.decode_weight(bw))
    print(f"\nBSTC lossless: {bool((rt == np.asarray(qw.q)).all())}, "
          f"CR = {bw.compression_ratio:.3f}x "
          f"(compressed planes: {[p+1 for p in range(7) if bw.encoded[p]]})")

    # --- BGPP: progressive top-k prediction --------------------------------
    S, D = 1024, 128
    k = np.clip(np.round(rng.normal(size=(S, D)) * 30), -127, 127).astype(np.int32)
    sign = jnp.asarray((k < 0).astype(np.uint8))
    magk = np.abs(k).astype(np.uint8)
    planes = jnp.asarray(np.stack([(magk >> p) & 1 for p in range(7)], 0))
    q = jnp.asarray(rng.integers(-60, 60, size=(D,)), jnp.int32)
    alive, est, stats = bgpp.bgpp_predict(
        q, planes, sign, bgpp.BGPPConfig(rounds=4, alpha=0.55),
        logit_scale=1.0 / np.sqrt(D) / 900.0,
    )
    true_top = np.argsort(k @ np.asarray(q))[-16:]
    recall = np.asarray(alive)[true_top].mean()
    print(f"\nBGPP kept {int(alive.sum())}/{S} keys, top-16 recall {recall:.2f}")
    print(f"predict traffic: {float(stats.predict_bytes):.0f} B vs "
          f"value-level {float(stats.value_topk_bytes):.0f} B "
          f"({100*(1-float(stats.predict_bytes)/float(stats.value_topk_bytes)):.0f}% saved)")


if __name__ == "__main__":
    main()
