"""End-to-end training driver: a ~100M-param dense LM for a few hundred
steps with the full substrate stack — synthetic data pipeline with prefetch,
AdamW (+optional int8 states), checkpointing, fault-tolerant resume,
straggler monitoring.

    PYTHONPATH=src python examples/train_llm.py [--steps 300] [--d-model 512]
        [--layers 8] [--int8-opt] [--ckpt-dir /tmp/mcbp_ckpt]

(A ~100M config is the default; pass --steps 30 for a quick run.)
"""

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig
from repro.data import Prefetcher, SyntheticLMDataset
from repro.distributed import sharding as sh
from repro.models import model_zoo
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import StragglerMonitor
from repro.training import make_train_step

jax.config.update("jax_platform_name", "cpu")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--int8-opt", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/mcbp_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = ModelConfig(
        name=f"train-demo-{args.d_model}d{args.layers}L",
        family="dense",
        num_layers=args.layers,
        d_model=args.d_model,
        num_heads=args.d_model // 64,
        num_kv_heads=max(1, args.d_model // 128),
        head_dim=64,
        d_ff=4 * args.d_model,
        vocab_size=args.vocab,
        activation="swiglu",
        norm="rms",
        dtype="float32",
    )
    print(f"[train] {cfg.name}: {cfg.total_params()/1e6:.1f}M params")

    params, _ = model_zoo.init(jax.random.key(0), cfg)
    opt_cfg = AdamWConfig(
        peak_lr=3e-4, warmup_steps=50, decay_steps=args.steps,
        state_dtype="int8" if args.int8_opt else "fp32",
    )
    state = {"params": params, "opt": adamw_init(params, opt_cfg)}
    step_fn = jax.jit(
        make_train_step(cfg, sh.ShardingRules(), opt_cfg,
                        fwd_kwargs=dict(block_q=64, block_k=128, remat=True))
    )

    ds = SyntheticLMDataset(cfg.vocab_size, args.seq_len, args.batch, seed=0)
    pf = Prefetcher(ds, depth=2)
    ckpt = Checkpointer(args.ckpt_dir, keep=2)
    # loose threshold: sub-10ms CPU steps jitter a lot relative to median
    monitor = StragglerMonitor(threshold=8.0)

    t_start = time.perf_counter()
    losses = []
    try:
        for i in range(args.steps):
            step, batch = pf.next()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            dt = time.perf_counter() - t0
            if monitor.record(step, dt):
                print(f"[train] straggler flagged at step {step} ({dt:.2f}s)")
            losses.append(float(metrics["loss"]))
            if step % 20 == 0 or step == args.steps - 1:
                tps = args.batch * args.seq_len / max(dt, 1e-9)
                print(f"[train] step {step:4d} loss {losses[-1]:7.4f} "
                      f"lr {float(metrics['lr']):.2e} gnorm "
                      f"{float(metrics['grad_norm']):.2f} ({tps:.0f} tok/s)")
            if step and step % args.ckpt_every == 0:
                ckpt.save(step, state)
    finally:
        pf.close()
        ckpt.wait()

    total = time.perf_counter() - t_start
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    print(f"[train] {args.steps} steps in {total:.1f}s; "
          f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    ckpt.save(args.steps, state)
    ckpt.wait()
    print(f"[train] final checkpoint at {args.ckpt_dir}")


if __name__ == "__main__":
    main()
